"""AdamW with selectable moment precision (fp32 / bf16 / int8 block-quant).

No optax in this environment — the optimizer is a pure ``init/update`` pair
over pytrees, which also keeps the sharding story simple: moment pytrees
mirror the parameter pytree, so ``param_specs`` applies verbatim (int8
moments carry per-block scales with a leading block dim; they stay
replicated — they are ~1/128 of the moment bytes).

``moment_dtype="int8"`` is the distributed-optimization trick from the
8-bit-Adam line of work (Dettmers et al.), simplified to symmetric linear
block quantization (block = 128): it cuts optimizer-state HBM and
checkpoint bytes by ~3.5× — the difference between kimi-k2 fitting a 512-
chip v5e slice or not (DESIGN.md §7, EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]

_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"  # float32 | bfloat16 | int8


# ---------------------------------------------------------------------- #
# int8 block quantization of moment tensors — SHAPE-PRESERVING: ``q`` has
# the parameter's exact shape (so the parameter sharding rules apply
# verbatim) and ``scale`` replaces the last dim by ceil(last/128) blocks.
# ---------------------------------------------------------------------- #
def _q8_nb(shape) -> int:
    last = shape[-1] if shape else 1
    return max(1, -(-last // _BLOCK))


def _q8_zeros(shape) -> dict:
    shape = tuple(shape)
    return {
        "q": jnp.zeros(shape if shape else (1,), jnp.int8),
        "scale": jnp.zeros((shape[:-1] if shape else ()) + (_q8_nb(shape),), jnp.float32),
    }


def _q8_encode(x: jax.Array) -> dict:
    shape = x.shape if x.shape else (1,)
    x = x.reshape(shape).astype(jnp.float32)
    last = shape[-1]
    nb = _q8_nb(shape)
    pad = nb * _BLOCK - last
    xp = jnp.pad(x, [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = xp.reshape(shape[:-1] + (nb, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # (..., nb)
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    q = q.reshape(shape[:-1] + (nb * _BLOCK,))[..., :last]
    return {"q": q, "scale": scale}


def _q8_decode(enc: dict, shape) -> jax.Array:
    shape = tuple(shape) if shape else (1,)
    last = shape[-1]
    nb = _q8_nb(shape)
    pad = nb * _BLOCK - last
    qp = jnp.pad(enc["q"].astype(jnp.float32), [(0, 0)] * (len(shape) - 1) + [(0, pad)])
    blocks = qp.reshape(shape[:-1] + (nb, _BLOCK)) * enc["scale"][..., None]
    out = blocks.reshape(shape[:-1] + (nb * _BLOCK,))[..., :last]
    return out.reshape(shape)


def _is_q8_leaf(x) -> bool:
    return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}


# ---------------------------------------------------------------------- #
def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    if cfg.moment_dtype == "int8":
        m = jax.tree.map(lambda p: _q8_zeros(p.shape), params)
        v = jax.tree.map(lambda p: _q8_zeros(p.shape), params)
    else:
        dt = jnp.dtype(cfg.moment_dtype)
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any,
    opt_state: dict,
    params: Any,
    lr: jax.Array,
    cfg: AdamWConfig,
) -> tuple[Any, dict]:
    """Returns (new_params, new_opt_state). Grads are fp32-accumulated."""
    count = opt_state["count"] + 1
    b1c = 1.0 - cfg.b1**count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2**count.astype(jnp.float32)
    q8 = cfg.moment_dtype == "int8"

    def upd_flat(p, g, m_st, v_st, ndim):
        g = g.astype(jnp.float32)
        m_prev = _q8_decode(m_st, p.shape) if q8 else m_st.astype(jnp.float32)
        v_prev = _q8_decode(v_st, p.shape) if q8 else v_st.astype(jnp.float32)
        m = cfg.b1 * m_prev + (1 - cfg.b1) * g
        v = cfg.b2 * v_prev + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (step + decay)).astype(p.dtype)
        if q8:
            return new_p, _q8_encode(m), _q8_encode(v)
        dt = jnp.dtype(cfg.moment_dtype)
        return new_p, m.astype(dt), v.astype(dt)

    # Leaves above this size run the update via lax.map over the leading
    # (layer-stack) dim: the fp32 working copies of a 61-layer-stacked
    # 1T-MoE expert tensor measured 10.7 GB/device EACH in the kimi
    # dry-run (EXPERIMENTS §Perf); chunking bounds them to one layer slice.
    chunk_threshold = 64 * 2**20  # bytes of fp32 working copy

    def upd(p, g, m_st, v_st, logical_ndim, stacked):
        if stacked and p.ndim >= 3 and p.size * 4 > chunk_threshold:
            def one(args):
                pp, gg, mm, vv = args
                return upd_flat(pp, gg, mm, vv, logical_ndim)

            return jax.lax.map(one, (p, g, m_st, v_st))
        return upd_flat(p, g, m_st, v_st, logical_ndim)

    flat_pp, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_p = [leaf for _, leaf in flat_pp]
    # Weight decay applies to logical matrices; scanned (layer-stacked)
    # leaves carry one extra leading dim that must not count.
    stacked_flags = []
    logical_ndims = []
    for path, leaf in flat_pp:
        keys = {str(getattr(e, "key", "")) for e in path}
        stacked = "scan" in keys
        stacked_flags.append(stacked)
        logical_ndims.append(leaf.ndim - (1 if stacked else 0))
    flat_g = treedef.flatten_up_to(grads)
    is_leaf = _is_q8_leaf if q8 else None
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_leaf)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_leaf)[0]
    out = [
        upd(p, g, m, v, ln, sf)
        for p, g, m, v, ln, sf in zip(
            flat_p, flat_g, flat_m, flat_v, logical_ndims, stacked_flags
        )
    ]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}
