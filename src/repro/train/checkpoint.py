"""Sharded checkpointing: async save, atomic commit, elastic restore.

Layout (self-describing, no pickle):

    <dir>/ckpt_<step>/manifest.json   # pytree structure + shapes + dtypes
    <dir>/ckpt_<step>/arrays.npz      # one entry per leaf (path-keyed)

Fault-tolerance properties:

  * **Atomic commit** — writes land in ``.tmp-<step>`` and are renamed into
    place; a crash mid-write can never produce a half checkpoint that
    ``latest_step`` would pick up.
  * **Async** — ``save(..., blocking=False)`` snapshots to host (device_get)
    synchronously, then writes on a daemon thread; ``wait()`` joins. The
    training loop only stalls for the device→host copy.
  * **Elastic restore** — arrays are stored unsharded (host view); restore
    applies *current-mesh* shardings, so resuming on a different device
    count/mesh Just Works (sharding rules are divisibility-aware).
  * **Keep-policy** — ``gc(keep=n)`` prunes old steps, never the newest.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["Checkpointer", "save_checkpoint", "restore_checkpoint", "latest_step"]

_SEP = "||"


def _flatten(state: Any):
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_checkpoint(
    directory: str, step: int, state: Any, *, blocking: bool = True
) -> threading.Thread | None:
    os.makedirs(directory, exist_ok=True)
    host = _flatten(state)
    treedef = jax.tree_util.tree_structure(state)
    manifest = {
        "step": int(step),
        "keys": list(host.keys()),
        "treedef": str(treedef),
        "shapes": {k: list(v.shape) for k, v in host.items()},
        "dtypes": {k: str(v.dtype) for k, v in host.items()},
    }

    def _write():
        tmp = os.path.join(directory, f".tmp-{step}")
        final = os.path.join(directory, f"ckpt_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("ckpt_") and os.path.exists(
            os.path.join(directory, d, "manifest.json")
        )
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    like: Any,
    *,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (values replaced).

    ``shardings``: optional pytree of NamedShardings (current mesh) — this
    is the elastic-reshard path.  Returns (state, step).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    flat_like, treedef = leaves_with_path
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    out = []
    for i, (p, leaf) in enumerate(flat_like):
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        arr = data[key]
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    )
    return state, step


class Checkpointer:
    """Stateful helper tying save/restore/gc/async together."""

    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, state, blocking=not self.async_save
        )
        self.gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, like: Any, *, shardings: Any = None):
        self.wait()
        return restore_checkpoint(self.directory, like, shardings=shardings)

    def gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("ckpt_")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"ckpt_{s:08d}"), ignore_errors=True
            )
