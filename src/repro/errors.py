"""Typed failure taxonomy for the whole serving stack.

Every failure the query path can surface is a :class:`TrussError` subclass
carrying enough context to *act* on — which shape bucket, which registry
backend, which packed slot / query — instead of a bare ``ValueError`` or
``RuntimeError`` that forces callers to parse messages.  The taxonomy is
what the resilience layer (``repro.resilience``) keys its policy on:

* :class:`InvalidGraphError` — the input itself is bad (malformed CSR,
  slot-capacity overflow, poisoned batch member).  Deterministic: never
  retried; the offending query is quarantined so its batch-mates survive.
* :class:`CompileError` — building/compiling a bucket's executable
  failed.  Deterministic for a given backend: not retried on the same
  backend, but the planner falls down the registry fallback chain
  (pallas→xla, fine→coarse) because every backend is bit-identical.
* :class:`DeviceError` — the dispatch itself failed (kernel fault,
  ``oom=True`` for resource exhaustion).  Potentially transient: retried
  with exponential backoff, then falls back.
* :class:`QueryFailedError` — the terminal per-query verdict after
  retries/fallbacks/bisection are exhausted; ``cause`` keeps the last
  underlying typed error.
* :class:`TrussTimeoutError` — a future's wait budget expired; with
  ``shed=True`` the query was marked dead and its slot reclaimed.
* :class:`CheckpointError` — a streaming checkpoint failed to write,
  parse, or verify (``repro.resilience.checkpoint``).

This module lives at the repo root of the ``repro`` namespace (no
intra-repo imports) so low-level layers — ``graphs.csr`` validation,
``exec.peel`` — can raise typed errors without import cycles;
``repro.api.errors`` re-exports the taxonomy as the public surface.
"""

from __future__ import annotations

__all__ = [
    "TrussError",
    "InvalidGraphError",
    "CompileError",
    "DeviceError",
    "QueryFailedError",
    "TrussTimeoutError",
    "CheckpointError",
]


class TrussError(Exception):
    """Base of the typed taxonomy; carries serving context as attributes.

    ``bucket`` / ``backend`` are the shape bucket and registry backend the
    failing work was assigned to (kept as their original objects, not
    stringified, so callers can compare against ``bucket_for`` /
    ``BackendKey`` values).  ``slot`` / ``query_id`` attribute a failure
    to one member of a packed batch — the hook batch fault isolation
    quarantines on.  ``injected=True`` marks faults raised by the
    fault-injection harness (``repro.resilience.faults``), which the
    chaos suite uses to tell injected failures from organic ones.
    """

    def __init__(
        self,
        message: str,
        *,
        bucket=None,
        backend=None,
        slot: int | None = None,
        query_id: int | None = None,
        site: str | None = None,
        injected: bool = False,
        cause: BaseException | None = None,
    ):
        super().__init__(message)
        self.bucket = bucket
        self.backend = backend
        self.slot = slot
        self.query_id = query_id
        self.site = site
        self.injected = bool(injected)
        self.cause = cause

    def context(self) -> dict:
        """The non-empty context fields, JSON-friendly (for logs/metrics)."""
        out = {}
        for k in ("bucket", "backend", "slot", "query_id", "site"):
            v = getattr(self, k)
            if v is not None:
                out[k] = str(v) if k in ("bucket", "backend") else v
        if self.injected:
            out["injected"] = True
        return out


class InvalidGraphError(TrussError, ValueError):
    """The input graph (or one packed member) violates a CSR invariant.

    ``row`` is the first violating 1-based row and ``kind`` names the
    broken invariant (``rowptr_unsorted`` / ``rowptr_mismatch`` /
    ``col_range`` / ``self_loop`` / ``unsorted_row`` / ``duplicate`` /
    ...), so callers and tests can assert on *which* invariant failed.
    Subclasses ``ValueError`` so pre-taxonomy ``except ValueError``
    callers keep working.
    """

    def __init__(
        self,
        message: str,
        *,
        row: int | None = None,
        kind: str | None = None,
        graph: str | None = None,
        **ctx,
    ):
        super().__init__(message, **ctx)
        self.row = row
        self.kind = kind
        self.graph = graph


class CompileError(TrussError, RuntimeError):
    """Building or compiling a bucket's executable failed (deterministic
    per backend — the resilience layer falls back instead of retrying)."""


class DeviceError(TrussError, RuntimeError):
    """A device dispatch failed; ``oom=True`` flags resource exhaustion."""

    def __init__(self, message: str, *, oom: bool = False, **ctx):
        super().__init__(message, **ctx)
        self.oom = bool(oom)


class QueryFailedError(TrussError, RuntimeError):
    """Terminal per-query failure after the resilience policy is exhausted.

    ``attempts`` counts dispatch attempts made on this query's behalf and
    ``backends_tried`` the registry keys walked; ``cause`` is the last
    underlying typed error (``CompileError`` / ``DeviceError`` / ...).
    """

    def __init__(
        self,
        message: str,
        *,
        attempts: int = 0,
        backends_tried: tuple = (),
        **ctx,
    ):
        super().__init__(message, **ctx)
        self.attempts = int(attempts)
        self.backends_tried = tuple(backends_tried)


class TrussTimeoutError(TrussError, TimeoutError):
    """``TrussFuture.result(timeout=...)`` expired before the query resolved.

    Carries enough context to act on — which shape bucket the request was
    waiting in and how deep the session's queue was at expiry — instead of
    a bare ``TimeoutError`` that forces callers to re-derive both.
    ``shed=True`` means the session marked the query dead on expiry (the
    default): its queue slot was reclaimed and later ``result()`` calls
    re-raise this error instead of re-dispatching abandoned work.
    """

    def __init__(
        self,
        message: str,
        *,
        bucket=None,
        queue_depth: int = 0,
        request_id: int | None = None,
        waited_s: float = 0.0,
        shed: bool = False,
        **ctx,
    ):
        super().__init__(message, bucket=bucket, query_id=request_id, **ctx)
        self.queue_depth = int(queue_depth)
        self.request_id = request_id
        self.waited_s = float(waited_s)
        self.shed = bool(shed)


class CheckpointError(TrussError, RuntimeError):
    """A streaming checkpoint failed to write, parse, or verify."""

    def __init__(self, message: str, *, path: str | None = None, **ctx):
        super().__init__(message, **ctx)
        self.path = path
